"""Fault-injection harness for the serving/robustness stack.

Production code declares *fault points* — named sites where a registered
handler may raise (`fire`) or rewrite a value in flight (`transform`).
With no handler registered both are free no-ops (one dict lookup), so the
hooks stay in the hot path permanently; tests arm them via the `injected`
context manager to prove each fault class either recovers or degrades to
the host-exact output (tests/test_serving_faults.py).

Fault points currently wired:

  ladder.<level>        fired before the degradation ladder runs backend
                        <level> ("pallas" | "plan" | "host") — raising here
                        simulates a kernel compile/launch failure
  ladder.out.<level>    transforms that level's output field — returning
                        NaNs simulates a numerically-broken kernel
  serve.step            fired before every ServeEngine batched decode with
                        tick=<int> — raising simulates a decode-step crash
  serve.logits          transforms the per-tick decode (B, V) numpy logits
                        with tick=<int> — NaN rows simulate per-slot
                        corruption
  serve.prefill         fired before a fused prefill-into-cache call with
                        tick=<int> — raising simulates a prefill crash
                        (the admitted group is evicted and re-queued)
  serve.prefill_logits  transforms the fused-prefill (B, V) numpy logits
                        with tick=<int> — NaN rows simulate per-slot
                        prefill corruption

Helpers below build the common fault shapes: `raise_at_tick`,
`nan_slot_at_tick`, `corrupt_file` (bit flips / truncation for artifact
tests) and `flip_index` (out-of-bounds index corruption on a PlanSpec).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable

import numpy as np

_active: dict[str, Callable] = {}


def inject(point: str, handler: Callable) -> None:
    """Arm `handler` at `point`. fire-handlers take **ctx and may raise;
    transform-handlers take (value, **ctx) and return the replacement."""
    _active[point] = handler


def clear(point: str | None = None) -> None:
    if point is None:
        _active.clear()
    else:
        _active.pop(point, None)


@contextlib.contextmanager
def injected(point: str, handler: Callable):
    """Arm a handler for the duration of a with-block (always disarmed)."""
    inject(point, handler)
    try:
        yield
    finally:
        clear(point)


def active(point: str) -> bool:
    return point in _active


def fire(point: str, **ctx) -> None:
    """Invoke the handler at `point` (no-op when unarmed). The handler may
    raise — that IS the injected fault."""
    handler = _active.get(point)
    if handler is not None:
        handler(**ctx)


def transform(point: str, value, **ctx):
    """Pass `value` through the handler at `point` (identity when unarmed)."""
    handler = _active.get(point)
    return value if handler is None else handler(value, **ctx)


# ----------------------------------------------------------------------------
# handler factories / corruption helpers
# ----------------------------------------------------------------------------


def raise_at_tick(k: int, exc: type = RuntimeError,
                  msg: str = "injected fault") -> Callable:
    """fire-handler: raise `exc` exactly when ctx tick == k."""

    def handler(**ctx):
        if ctx.get("tick") == k:
            raise exc(f"{msg} (tick {k})")

    return handler


def always_raise(exc: type = RuntimeError,
                 msg: str = "injected fault") -> Callable:
    def handler(**ctx):
        raise exc(msg)

    return handler


def nan_output() -> Callable:
    """transform-handler: replace the whole output with NaNs (broken
    kernel writing garbage)."""

    def handler(value, **ctx):
        import jax.numpy as jnp

        return jnp.full_like(value, jnp.nan)

    return handler


def nan_slot_at_tick(slot: int, k: int) -> Callable:
    """transform-handler for serve.logits: NaN one slot's logits row at
    tick k (per-request corruption that must not kill the batch)."""

    def handler(value, *, tick=None, **ctx):
        if tick == k:
            value = np.array(value, copy=True)
            value[slot] = np.nan
        return value

    return handler


def corrupt_file(path, *, flip_bytes: int = 0, truncate_to: int | None = None,
                 seed: int = 0) -> None:
    """Corrupt an artifact on disk: XOR-flip `flip_bytes` random bytes
    and/or truncate the file to `truncate_to` bytes."""
    with open(path, "rb") as fh:
        data = bytearray(fh.read())
    if truncate_to is not None:
        data = data[:truncate_to]
    if flip_bytes and data:
        rng = np.random.default_rng(seed)
        # skip the first 512 bytes: flipping the zip local-file header makes
        # every corruption a trivial "not an npz" parse error; flipping the
        # payload exercises the semantic validation path
        lo = min(512, len(data) - 1)
        for pos in rng.integers(lo, len(data), size=flip_bytes):
            data[pos] ^= 0xFF
    with open(path, "wb") as fh:
        fh.write(bytes(data))


def flip_index(spec, field: str = "src_gather", entry: int = 0,
               value: int | None = None):
    """A copy of `spec` with one index entry flipped out of bounds (default:
    way past the vertex space) — the exact corruption class the plan guard
    exists to catch before the fused gather dereferences it."""
    arr = np.array(getattr(spec, field), copy=True)
    arr[entry] = (2 ** 30) if value is None else value
    return dataclasses.replace(spec, **{field: arr})
