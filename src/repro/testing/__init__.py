"""Testing utilities shipped with the library (fault injection points are
referenced from production code, so they live in-tree, not under tests/)."""
