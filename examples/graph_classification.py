"""Paper Sec 4.2: graph classification with f-distance spectral features.

  PYTHONPATH=src python examples/graph_classification.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.bench_graph_classification import (cross_val_accuracy,
                                                   features_bgfi,
                                                   features_ftfi, make_dataset)

graphs, labels = make_dataset(n_per_class=20)
print(f"dataset: {len(graphs)} graphs, 3 procedural families "
      "(TUDataset stand-in, DESIGN §7)")

fa, ta = features_ftfi(graphs)
acc_a, std_a = cross_val_accuracy(fa, labels)
print(f"FTFI tree-kernel features: acc={acc_a:.3f}±{std_a:.3f} "
      f"(feature time {ta:.2f}s)")

fb, tb = features_bgfi(graphs)
acc_b, std_b = cross_val_accuracy(fb, labels)
print(f"BGFI exact graph kernel:   acc={acc_b:.3f}±{std_b:.3f} "
      f"(feature time {tb:.2f}s)")
print(f"feature-processing time reduction: {(tb-ta)/tb*100:.1f}%")
