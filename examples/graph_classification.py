"""Paper Sec 4.2: graph classification with f-distance spectral features.

The tree-kernel features ride the FOREST path: every graph's MST is packed
into one `Forest`, and a single fused plan execution returns all kernels in
one jit dispatch (vs the per-graph host loop it is timed against).

  PYTHONPATH=src python examples/graph_classification.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.bench_graph_classification import (cross_val_accuracy,
                                                   features_bgfi,
                                                   features_forest,
                                                   features_ftfi, make_dataset)

graphs, labels = make_dataset(n_per_class=20)
print(f"dataset: {len(graphs)} graphs, 3 procedural families "
      "(TUDataset stand-in, DESIGN §7)")

t0 = time.perf_counter()
fa = features_forest(graphs)  # one fused forest plan for all graphs
t_cold = time.perf_counter() - t0  # includes one-off jit compile + plan build
t0 = time.perf_counter()
fa = features_forest(graphs)  # steady state: content-hash caches + jit warm
ta = time.perf_counter() - t0
acc_a, std_a = cross_val_accuracy(fa, labels)
print(f"FTFI forest-packed features: acc={acc_a:.3f}±{std_a:.3f} "
      f"(feature time {ta*1e3:.1f}ms steady / {t_cold:.2f}s cold, "
      "one fused dispatch)")

t0 = time.perf_counter()
fl = features_ftfi(graphs)  # the per-graph host loop baseline
tl = time.perf_counter() - t0
acc_l, std_l = cross_val_accuracy(fl, labels)
print(f"FTFI per-graph host loop:    acc={acc_l:.3f}±{std_l:.3f} "
      f"(feature time {tl*1e3:.1f}ms)")

t0 = time.perf_counter()
fb = features_bgfi(graphs)
tb = time.perf_counter() - t0
acc_b, std_b = cross_val_accuracy(fb, labels)
print(f"BGFI exact graph kernel:     acc={acc_b:.3f}±{std_b:.3f} "
      f"(feature time {tb:.2f}s)")
print(f"forest vs per-graph loop: {tl/max(ta,1e-12):.2f}x; "
      f"feature-processing time reduction vs BGFI: {(tb-ta)/tb*100:.1f}%")
