"""Quickstart: exact fast tree-field integration in five minutes.

  PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

from repro.core import BTFI, Exponential, Integrator, Polynomial, Rational
from repro.graphs.graph import synthetic_graph
from repro.graphs.mst import minimum_spanning_tree

# 1. A graph: path + random extra edges (paper Sec 4.1). FTFI integrates on
#    trees, so we approximate the graph metric with its MST metric.
n = 6000
graph = synthetic_graph(n, n // 2, seed=0)
tree = minimum_spanning_tree(graph)

# 2. A tensor field on the vertices.
rng = np.random.default_rng(0)
X = rng.normal(size=(n, 8))

# 3. Preprocess once (IntegratorTree, O(N log N)), integrate many times.
#    One API, swappable structured-multiply backends:
#      host   recursive numpy engines (exact; ExpMP fast path for exp)
#      plan   jit-able bucketed plan executor (exact LDR + Chebyshev)
#      pallas plan executor on the fused fdist_matvec TPU kernel
t0 = time.perf_counter()
integ = Integrator(tree, backend="host", leaf_size=256)
t_pre = time.perf_counter() - t0

for fn, name in [(Exponential(-0.5), "exp(-0.5 x)"),
                 (Polynomial((1.0, -0.3, 0.02)), "1 - 0.3x + 0.02x^2"),
                 (Rational((1.0,), (1.0, 0.0, 2.0)), "1/(1+2x^2)")]:
    t0 = time.perf_counter()
    out = integ.integrate(fn, X)
    t_fast = time.perf_counter() - t0
    engine = integ.describe(fn)["cross_engine"]
    print(f"f = {name:20s} integrated {n} vertices x 8 channels "
          f"in {t_fast*1e3:7.1f} ms  [{engine}]")

# 4. Exactness: identical to brute force (materialized N x N kernel).
t0 = time.perf_counter()
btfi = BTFI(tree, dtype=np.float32)
t_pre_b = time.perf_counter() - t0
fn = Exponential(-0.5)
t0 = time.perf_counter()
ref = btfi.integrate(fn, X)
t_brute = time.perf_counter() - t0
got = integ.integrate(fn, X)
err = np.max(np.abs(got - ref)) / np.max(np.abs(ref))
print(f"\nexact vs brute force: rel err = {err:.2e}")
print(f"preprocessing: Integrator {t_pre:.2f}s vs BTFI {t_pre_b:.2f}s "
      f"({t_pre_b/max(t_pre, 1e-9):.1f}x)")

# 5. The jit-able backends agree too (compiled once, reused per field).
sub_n = 1500
sub = minimum_spanning_tree(synthetic_graph(sub_n, sub_n // 2, seed=1))
Xs = rng.normal(size=(sub_n, 8))
ref = BTFI(sub).integrate(fn, Xs)
for backend in ("plan", "pallas"):
    ii = Integrator(sub, backend=backend, leaf_size=64)
    t0 = time.perf_counter()
    got = np.asarray(ii.integrate(fn, Xs))
    dt = time.perf_counter() - t0
    err = np.max(np.abs(got - ref)) / np.max(np.abs(ref))
    print(f"backend={backend:6s} rel err vs BTFI = {err:.2e}  "
          f"({dt*1e3:.1f} ms, engine={ii.describe(fn)['cross_engine']})")

# 6. Functional plan API: static PlanSpec (pytree aux) + differentiable
#    PlanParams (pytree leaves). Pure (params, X) -> Y crosses jit
#    boundaries explicitly — vmap over batched fields, checkpoint/serve the
#    plan, and (with reweightable=True) train the tree metric itself.
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import ftfi  # noqa: E402

spec, params = ftfi.build(sub, leaf_size=64)
fm = jax.jit(ftfi.fastmult(spec, fn))
got = np.asarray(fm(params, Xs))
err = np.max(np.abs(got - ref)) / np.max(np.abs(ref))
print(f"\nftfi.apply (jitted)   rel err vs BTFI = {err:.2e}  [{spec!r}]")

# learnable tree metric: gradients flow into edge weights via ftfi.reweight
small = minimum_spanning_tree(synthetic_graph(200, 100, seed=3))
rspec, _ = ftfi.build(small, leaf_size=32, reweightable=True)
w = jnp.asarray(small.weights, jnp.float32)
Xp = jnp.asarray(rng.normal(size=(200, 4)), jnp.float32)
g = jax.grad(lambda w_: jnp.sum(
    ftfi.apply(rspec, ftfi.reweight(rspec, w_), fn, Xp) ** 2))(w)
print(f"d(loss)/d(edge weights): shape={g.shape}, "
      f"|g|_1={float(jnp.sum(jnp.abs(g))):.3g}  (tree metric is trainable)")
