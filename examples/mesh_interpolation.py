"""Paper Sec 4.2: vertex-normal interpolation on meshes.

Mask 80% of vertex normals; reconstruct them by f-integrating the known ones
over the mesh MST with the rational kernel f(x) = 1/(1 + lambda x^2).

  PYTHONPATH=src python examples/mesh_interpolation.py
"""
import time

import numpy as np

from repro.core import Integrator, Rational
from repro.graphs.meshes import icosphere, mesh_graph, vertex_normals
from repro.graphs.mst import minimum_spanning_tree

rng = np.random.default_rng(0)
for subdiv in (3, 4):
    verts, faces = icosphere(subdiv)
    n = verts.shape[0]
    normals = vertex_normals(verts, faces)
    g = mesh_graph(verts, faces)
    mst = minimum_spanning_tree(g)

    known = rng.random(n) < 0.2  # keep 20%, mask 80% (paper protocol)
    F = np.where(known[:, None], normals, 0.0)

    t0 = time.perf_counter()
    integ = Integrator(mst, backend="host", leaf_size=256)
    t_pre = time.perf_counter() - t0

    best = (-1.0, None)
    for lam in (1.0, 4.0, 16.0):  # grid search as in the paper
        pred = integ.integrate(Rational((1.0,), (1.0, 0.0, lam)), F)
        pred /= np.maximum(np.linalg.norm(pred, axis=1, keepdims=True), 1e-12)
        cos = float(np.mean(np.sum(pred[~known] * normals[~known], axis=1)))
        if cos > best[0]:
            best = (cos, lam)
    print(f"icosphere/{subdiv}: n={n:6d} preprocess={t_pre*1e3:7.1f} ms  "
          f"cosine={best[0]:.4f} (lambda={best[1]})")
