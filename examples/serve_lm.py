"""Batched serving example: continuous-batching engine over a small LM.

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import numpy as np
import jax

from repro.configs.base import get_smoke_config
from repro.models import api
from repro.serve.engine import Request, ServeEngine

cfg = get_smoke_config("qwen2_1_5b").replace(dtype="float32")
params = api.init_params(cfg, jax.random.PRNGKey(0))
engine = ServeEngine(cfg, params, batch_slots=4, max_len=96)

rng = np.random.default_rng(0)
requests = [
    Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=6).tolist(),
            max_new_tokens=12)
    for i in range(10)
]
for r in requests:
    engine.submit(r)

t0 = time.time()
ticks = engine.run()
dt = time.time() - t0
tok = sum(len(r.out) for r in requests)
print(f"served {len(requests)} requests, {tok} tokens, {ticks} ticks, "
      f"{dt:.2f}s -> {tok/dt:.1f} tok/s (batched decode)")
for r in requests[:3]:
    print(f"  req {r.rid}: prompt={r.prompt} -> out={r.out}")
