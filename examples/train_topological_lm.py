"""End-to-end driver: train a small LM with the paper's Topological
Performer attention for a few hundred steps and compare against the
unmasked Performer baseline (the paper's Table-1 comparison, LM-scale).

  PYTHONPATH=src python examples/train_topological_lm.py [--steps 300]

The synthetic stream contains copy spans, so attention that can express
distance structure (the 3-parameter topological mask) has signal to win on.
"""
import argparse

import numpy as np

from repro.configs.base import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainLoopConfig, run_training


def small_lm(variant: str, seq_len: int, topo_impl: str = "fft",
             topo_degree: int = 1) -> ModelConfig:
    return ModelConfig(
        name=f"lm-{variant}", family="dense", num_layers=4, d_model=256,
        num_heads=4, num_kv_heads=4, head_dim=64, d_ff=1024, vocab_size=512,
        attention_variant=variant, performer_phi="relu", topo_g="exp",
        topo_degree=topo_degree, topo_synced=True,
        topo_dist_scale=1.0 / seq_len, topo_attn_impl=topo_impl,
        dtype="float32", tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--topo-impl", default="fft",
                    choices=("ref", "fft", "pallas"),
                    help="sequence-mask impl for the topo variant "
                         "(cfg.topo_attn_impl)")
    ap.add_argument("--topo-degree", type=int, default=1,
                    help="mask polynomial degree (2+ exercises the general "
                         "non-separable path)")
    args = ap.parse_args()

    results = {}
    for variant in ("performer", "topo"):
        cfg = small_lm(variant, args.seq, args.topo_impl, args.topo_degree)
        loop = TrainLoopConfig(
            steps=args.steps, batch_size=args.batch, seq_len=args.seq,
            ckpt_dir=f"/tmp/topolm_{variant}", ckpt_every=args.steps,
            log_every=max(1, args.steps // 6), seed=0)
        opt = AdamWConfig(lr=1e-3, total_steps=args.steps,
                          warmup_steps=args.steps // 10)
        print(f"\n=== training variant={variant} "
              f"({'3 extra mask params/layer' if variant == 'topo' else 'no mask'}) ===")
        res = run_training(cfg, loop, opt)
        results[variant] = res["losses"]

    tail = max(5, args.steps // 10)
    base = float(np.mean(results["performer"][-tail:]))
    topo = float(np.mean(results["topo"][-tail:]))
    print("\n=== summary (mean loss over final steps) ===")
    print(f"performer (unmasked): {base:.4f}")
    print(f"topological (masked): {topo:.4f}")
    print(f"delta: {base - topo:+.4f} "
          f"({'topological mask wins' if topo < base else 'baseline wins'})")


if __name__ == "__main__":
    main()
